"""Unit + behaviour tests for the SuperNIC core policy library."""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.core import (PAPER, SNIC, ChainProgram, EventSim, NTDag, NTSpec,
                        OutOfMemory, SNICConfig, VirtualMemory, analyze,
                        drf_allocate, enumerate_programs, make_rack,
                        rack_analysis)
from repro.core.regions import RegionManager, RegionState
from repro.core.sim import GBPS, MS, US, poisson_source

SPECS = {f"NT{i}": NTSpec(f"NT{i}", max_gbps=100.0, fixed_ns=100.0)
         for i in range(1, 9)}


def chain_dag(uid, tenant, names):
    return NTDag(uid, tenant, ((tuple(names),),))


def mk_snic(sim, mode="snic", **kw):
    kw.setdefault("enable_drf", False)
    kw.setdefault("enable_autoscale", False)
    return SNIC(sim, SNICConfig(mode=mode, **kw), SPECS)


# =============================================================== EventSim ====
class TestEventSim:
    def test_idle_window_advances_clock(self):
        """An idle run (no events in the window) must still move the clock
        to the horizon — regression for the old finalization that pinned
        ``now`` at the last processed event whenever events remained past
        the horizon."""
        sim = EventSim()
        fired = []
        sim.at(100.0, fired.append, "late")
        assert sim.run(until_ns=50.0) == 0          # event is past horizon
        assert sim.now == 50.0                      # ... clock still advances
        assert sim.run(until_ns=30.0) == 0
        assert sim.now == 50.0                      # never goes backwards
        assert sim.run(until_ns=1000.0) == 1
        assert fired == ["late"]
        assert sim.now == 1000.0

    def test_empty_sim_advances_to_horizon(self):
        sim = EventSim()
        assert sim.run(until_ns=200.0) == 0
        assert sim.now == 200.0

    def test_infinite_horizon_stops_at_last_event(self):
        sim = EventSim()
        sim.at(7.0, lambda: None)
        sim.run()                                   # until_ns=inf drains all
        assert sim.now == 7.0                       # ... and stays finite

    def test_max_events_budget_leaves_clock_at_last_processed(self):
        """Exiting on the event budget must not advance the clock to an
        event that was never processed."""
        sim = EventSim()
        for t in (10.0, 20.0, 30.0):
            sim.at(t, lambda: None)
        assert sim.run(until_ns=100.0, max_events=1) == 1
        assert sim.now == 10.0
        assert sim.run(until_ns=100.0) == 2         # drain the rest
        assert sim.now == 100.0


# ==================================================================== DRF ====
class TestDRF:
    def test_classic_two_tenant(self):
        # Ghodsi et al. example: A wants (1 CPU, 4 GB), B wants (3 CPU, 1 GB)
        # of (9 CPU, 18 GB): A -> 3 tasks, B -> 2 tasks at equilibrium.
        demands = {"A": {"cpu": 10 * 1, "mem": 10 * 4},
                   "B": {"cpu": 10 * 3, "mem": 10 * 1}}
        res = drf_allocate(demands, {"cpu": 9, "mem": 18})
        a_tasks = res.alloc["A"]["cpu"] / 1
        b_tasks = res.alloc["B"]["cpu"] / 3
        assert a_tasks == pytest.approx(3, abs=0.05)
        assert b_tasks == pytest.approx(2, abs=0.05)
        assert res.dominant["A"] == "mem" and res.dominant["B"] == "cpu"

    def test_weighted(self):
        demands = {"A": {"bw": 100.0}, "B": {"bw": 100.0}}
        res = drf_allocate(demands, {"bw": 90.0}, weights={"A": 2.0, "B": 1.0})
        assert res.alloc["A"]["bw"] == pytest.approx(60.0, rel=0.02)
        assert res.alloc["B"]["bw"] == pytest.approx(30.0, rel=0.02)

    def test_undemanding_tenant_fully_granted(self):
        demands = {"A": {"bw": 1000.0}, "B": {"bw": 1.0}}
        res = drf_allocate(demands, {"bw": 100.0})
        assert res.alloc["B"]["bw"] == pytest.approx(1.0, rel=0.01)
        assert res.alloc["A"]["bw"] <= 100.0

    def test_work_conserving(self):
        demands = {"A": {"bw": 80.0}, "B": {"bw": 80.0}}
        res = drf_allocate(demands, {"bw": 100.0})
        total = res.alloc["A"]["bw"] + res.alloc["B"]["bw"]
        assert total == pytest.approx(100.0, rel=0.02)

    def test_multi_resource_nt_dimension(self):
        # A saturates NT1, B saturates NT2: both should get ~full demand
        demands = {"A": {"nt:NT1": 100.0, "ingress": 10.0},
                   "B": {"nt:NT2": 100.0, "ingress": 10.0}}
        res = drf_allocate(demands, {"nt:NT1": 100.0, "nt:NT2": 100.0,
                                     "ingress": 100.0})
        assert res.scale("A") == pytest.approx(1.0, abs=0.01)
        assert res.scale("B") == pytest.approx(1.0, abs=0.01)


# =================================================================== vmem ====
class TestVMem:
    def test_on_demand_alloc_and_hit(self):
        vm = VirtualMemory(8 << 21)  # 8 pages
        vm.register("a")
        lat = vm.access("a", 0, 0.0)
        assert lat >= 100.0 and vm.resident_pages("a") == 1
        assert vm.access("a", 0, 1.0) == pytest.approx(100.0)

    def test_isolation(self):
        vm = VirtualMemory(8 << 21)
        vm.register("a")
        with pytest.raises(PermissionError):
            vm.access("b", 0, 0.0)

    def test_oversubscription_swaps_lru(self):
        vm = VirtualMemory(4 << 21)  # 4 frames
        vm.register("a"), vm.register("b")
        for i in range(3):
            vm.access("a", i, float(i))
        vm.access("b", 0, 10.0)
        assert not vm.free_frames
        # b's next page must swap out a's LRU page (vpage 0)
        lat = vm.access("b", 1, 11.0)
        assert lat >= vm.swap_ns
        assert vm.stats.swap_outs == 1
        assert vm.tables["a"][0].swapped
        # touching the swapped page swaps it back in
        lat = vm.access("a", 0, 12.0)
        assert lat >= 2 * vm.swap_ns  # evict someone + swap in
        assert vm.stats.swap_ins == 1

    def test_quota_denies(self):
        vm = VirtualMemory(8 << 21)
        vm.register("a")
        vm.quota["a"] = 2
        vm.access("a", 0, 0.0), vm.access("a", 1, 0.0)
        with pytest.raises(OutOfMemory):
            vm.access("a", 2, 0.0)

    def test_no_remote_space_rejects(self):
        vm = VirtualMemory(2 << 21, remote_free=lambda: False)
        vm.register("a")
        vm.access("a", 0, 0.0), vm.access("a", 1, 0.0)
        with pytest.raises(OutOfMemory):
            vm.access("a", 2, 0.0)

    def test_release_frees(self):
        vm = VirtualMemory(4 << 21)
        vm.register("a")
        for i in range(4):
            vm.access("a", i, 0.0)
        assert vm.release("a") == 4
        assert len(vm.free_frames) == 4


# ================================================================ regions ====
class TestRegions:
    def test_bitstream_enumeration(self):
        dags = [chain_dag(1, "u1", ("NT1", "NT2", "NT3"))]
        progs = enumerate_programs(dags, SPECS, region_slots=2)
        names = {p.names for p in progs}
        assert ("NT1", "NT2") in names and ("NT2", "NT3") in names
        assert ("NT1", "NT2", "NT3") not in names  # exceeds region
        assert ("NT1",) in names

    def test_victim_cache_revival_skips_pr(self):
        rm = RegionManager(2, 4, SPECS, pr_ns=PAPER.PR_NS)
        p1 = ChainProgram(("NT1", "NT2"))
        r1 = rm.launch(p1, 0.0)
        assert r1.did_pr and r1.ready_ns == PAPER.PR_NS
        rm.finish_pr(r1.region)
        rm.deschedule(r1.region, 1.0 * MS)
        # revival: instant, no PR
        r2 = rm.launch(p1, 2.0 * MS)
        assert r2.victim_revived and not r2.did_pr
        assert r2.ready_ns == 2.0 * MS
        assert rm.pr_count == 1

    def test_policy_ladder_free_then_victim_then_ctx(self):
        rm = RegionManager(2, 4, SPECS, pr_ns=1000.0)
        a = rm.launch(ChainProgram(("NT1",)), 0.0); rm.finish_pr(a.region)
        b = rm.launch(ChainProgram(("NT2",)), 0.0); rm.finish_pr(b.region)
        rm.deschedule(b.region, 10.0)  # b is a victim now
        c = rm.launch(ChainProgram(("NT3",)), 20.0)
        assert c.region is b.region and not c.context_switched
        rm.finish_pr(c.region)
        d = rm.launch(ChainProgram(("NT4",)), 30.0)
        assert d.context_switched  # no free/victim left

    def test_no_context_switch_flag(self):
        rm = RegionManager(1, 4, SPECS, pr_ns=1000.0)
        a = rm.launch(ChainProgram(("NT1",)), 0.0); rm.finish_pr(a.region)
        b = rm.launch(ChainProgram(("NT2",)), 1.0,
                      allow_context_switch=False)
        assert b.region is None

    def test_load_balanced_pick(self):
        rm = RegionManager(2, 4, SPECS, pr_ns=0.0)
        a = rm.launch(ChainProgram(("NT1",)), 0.0); rm.finish_pr(a.region)
        b = rm.launch(ChainProgram(("NT1",)), 0.0); rm.finish_pr(b.region)
        a.region.instances[0].busy_until_ns = 500.0
        pick = rm.find_program(("NT1",), now_ns=0.0)
        assert pick is b.region


# ============================================================== scheduler ====
class TestScheduler:
    def test_chain_single_sched_visit(self):
        """sNIC mode: a 4-NT chain is one scheduler visit (§4.2)."""
        sim = EventSim()
        nic = mk_snic(sim)
        dag = chain_dag(1, "u1", ("NT1", "NT2", "NT3", "NT4"))
        nic.deploy([dag], programs=[ChainProgram(("NT1", "NT2", "NT3", "NT4"))])
        sim.run(PAPER.PR_NS + 1)  # let prelaunch PR finish
        done = []
        nic.done_hook = lambda p: done.append(p)
        nic.inject("u1", 1, 1000)
        sim.run(sim.now + 1 * MS)
        assert len(done) == 1
        assert done[0].sched_visits == 1

    def test_panic_vs_chain_latency_under_load(self):
        """PANIC bounces between NTs under credit contention -> higher
        latency and more scheduler visits (Fig 15)."""
        res = {}
        for mode in ("snic", "panic"):
            sim = EventSim()
            nic = mk_snic(sim, mode=mode, credits=2)
            names = ("NT1", "NT2", "NT3", "NT4", "NT5")
            dag = chain_dag(1, "u1", names)
            nic.deploy([dag], programs=[ChainProgram(names)])
            sim.run(PAPER.PR_NS + 1)
            poisson_source(sim, rate_gbps=90.0, mean_bytes=1500, tenant="u1",
                           dag_uid=1, sink=nic.inject, seed=3,
                           until_ns=sim.now + 2 * MS)
            sim.run(sim.now + 4 * MS)
            st = nic.stats["u1"]
            visits = st.pkts_done and sum(
                1 for _ in st.latencies_ns)  # completed count
            res[mode] = (st.mean_latency_us(), st.pkts_done)
        assert res["panic"][0] > res["snic"][0]

    def test_fork_join_parallelism(self):
        """NT-level parallelism: two parallel branches then a join (Fig 16)."""
        sim = EventSim()
        nic = mk_snic(sim)
        # slow NTs to make serial vs parallel visible
        slow = {n: NTSpec(n, max_gbps=10.0, fixed_ns=5000.0)
                for n in ("NT1", "NT2", "NT3", "NT4")}
        nic.specs = slow
        nic.regions.specs = slow
        par = NTDag(1, "u1", ((("NT1", "NT2"), ("NT3",)), (("NT4",),)))
        ser = chain_dag(2, "u1", ("NT1", "NT2", "NT3", "NT4"))
        nic.deploy([par, ser])
        sim.run(PAPER.PR_NS * 10)
        lat = {}
        for uid, tag in ((1, "par"), (2, "ser")):
            done = []
            nic.done_hook = lambda p: done.append(p)
            nic.inject("u1", uid, 1000)
            sim.run(sim.now + 5 * MS)
            assert done, tag
            lat[tag] = done[-1].latency_ns
        # parallel: max(NT1+NT2, NT3) + NT4 < serial: NT1+NT2+NT3+NT4
        assert lat["par"] < lat["ser"]

    def test_skip_support(self):
        """A branch using a subsequence of a region's chain works (§4.2)."""
        sim = EventSim()
        nic = mk_snic(sim)
        full = chain_dag(1, "u1", ("NT1", "NT2", "NT3"))
        skip = chain_dag(2, "u1", ("NT1", "NT3"))  # skips NT2
        nic.deploy([full, skip],
                   programs=[ChainProgram(("NT1", "NT2", "NT3"))])
        sim.run(PAPER.PR_NS + 1)
        done = []
        nic.done_hook = lambda p: done.append(p)
        nic.inject("u1", 2, 500)
        sim.run(sim.now + 1 * MS)
        assert len(done) == 1 and done[0].sched_visits == 1

    def test_throughput_vs_credits(self):
        """More credits -> higher throughput; 8 reaches line rate (Fig 14)."""
        tput = {}
        for credits in (1, 8):
            sim = EventSim()
            nic = mk_snic(sim, credits=credits)
            dag = chain_dag(1, "u1", ("NT1",))
            nic.deploy([dag])
            sim.run(PAPER.PR_NS + 1)
            t0 = sim.now
            poisson_source(sim, rate_gbps=98.0, mean_bytes=1000, tenant="u1",
                           dag_uid=1, sink=nic.inject, seed=1,
                           until_ns=t0 + 3 * MS)
            sim.run(t0 + 3 * MS)
            tput[credits] = nic.stats["u1"].gbps(sim.now - t0)
        assert tput[8] > tput[1] * 1.2
        assert tput[8] > 80.0  # near line rate

    def test_on_demand_launch_buffers_first_packets(self):
        """On-demand launch pays PR once; packets buffered then served."""
        sim = EventSim()
        nic = mk_snic(sim)
        dag = chain_dag(1, "u1", ("NT1", "NT2"))
        nic.deploy([dag], prelaunch=False)
        done = []
        nic.done_hook = lambda p: done.append(p)
        nic.inject("u1", 1, 1000)
        sim.run(sim.now + PAPER.PR_NS * 3)
        assert len(done) == 1
        assert done[0].latency_ns >= PAPER.PR_NS  # waited for PR


# ============================================================ consolidation ==
class TestConsolidation:
    def test_sum_of_peaks_geq_aggregate(self):
        from repro.core.consolidation import synthetic_trace
        loads = synthetic_trace(8, 512, seed=1)
        rep = analyze(loads)
        assert rep.sum_of_peaks >= rep.peak_of_aggregate
        assert rep.savings > 1.3  # bursty non-aligned peaks consolidate well

    def test_rack_hierarchy(self):
        from repro.core.consolidation import synthetic_trace
        loads = synthetic_trace(64, 512, seed=2)
        r = rack_analysis(loads, rack_size=8)
        assert (r["sum_of_endpoint_peaks"] >= r["sum_of_rack_peaks"]
                >= r["peak_of_aggregate"])
        assert r["global_saving"] > r["rack_saving"] > 1.0

    def test_fb_trace_quantiles(self):
        from repro.core.consolidation import fb_kv_load_trace
        loads = fb_kv_load_trace(4, 4000, seed=3)
        med = float(np.median(loads))
        assert 18.0 < med < 30.0  # paper: median 24 Gbps

    def test_rack_analysis_uneven_tail_rack(self):
        """rack_size not dividing n_endpoints: the tail rack holds the
        remainder and its peak still counts (5 endpoints @ rack_size=2 ->
        racks of 2, 2, 1)."""
        loads = np.zeros((5, 4))
        for i in range(5):
            loads[i, i % 4] = 10.0 * (i + 1)   # distinct, non-aligned peaks
        r = rack_analysis(loads, rack_size=2)
        # racks: {e0,e1}, {e2,e3}, {e4}; peaks: 20, 40, 50
        assert r["sum_of_rack_peaks"] == pytest.approx(110.0)
        assert r["sum_of_endpoint_peaks"] == pytest.approx(150.0)
        # the tail rack (one endpoint) consolidates nothing: its peak is
        # the endpoint's own peak
        tail = rack_analysis(loads[4:5], rack_size=2)
        assert tail["sum_of_rack_peaks"] == pytest.approx(50.0)

    def test_onoff_source_resumes_from_boundary_aligned_off(self):
        """Regression: a phase-shifted on/off source that starts OFF with a
        period-grid-aligned clock must wake at the next ON *start* — the
        old retry delay landed exactly on the ON window's END and parked
        the source in OFF forever."""
        from repro.core.sim import onoff_source
        sim = EventSim()
        period = 800_000.0
        sim.run(5_080_001.0)               # e.g. a post-settle clock
        got = []
        onoff_source(sim, tenant="t", dag_uid=1,
                     sink=lambda *a: got.append(sim.now),
                     peak_gbps=10.0, duty=0.5, period_ns=period,
                     phase=0.25, until_ns=sim.now + 4 * MS)
        sim.run(sim.now + 4 * MS)
        assert got, "source never emitted"
        # every emission falls inside the shifted ON half of the period
        for t in got:
            assert ((t + 0.25 * period) % period) < 0.5 * period

    def test_rack_analysis_rejects_bad_inputs(self):
        loads = np.ones((4, 8))
        for bad in (0, -1, 2.5):
            with pytest.raises(ValueError, match="rack_size"):
                rack_analysis(loads, rack_size=bad)
        with pytest.raises(ValueError, match="matrix"):
            rack_analysis(np.ones(8), rack_size=2)
        with pytest.raises(ValueError, match="matrix"):
            rack_analysis(np.ones((0, 8)), rack_size=2)


# ================================================================== rack ====
class TestDistributed:
    def test_offload_and_migrate_back(self):
        sim = EventSim()
        rack = make_rack(sim, 2, SPECS,
                         cfg_kw=dict(n_regions=1, region_slots=4,
                                     enable_drf=False,
                                     enable_autoscale=False))
        a, b = rack.snics
        # fill a's only region with dag1, then dag2 must offload to b
        d1 = chain_dag(1, "u1", ("NT1",))
        d2 = chain_dag(2, "u2", ("NT2",))
        a.deploy([d1])
        sim.run(PAPER.PR_NS + 1)
        a.inject("u1", 1, 500)          # d1's region is now in active use
        sim.run(sim.now + 1 * MS)
        a.deploy([d2], prelaunch=False)
        done = []
        a.done_hook = lambda p: done.append(p)
        b.done_hook = lambda p: done.append(p)
        a.inject("u2", 2, 800)
        sim.run(sim.now + PAPER.PR_NS * 3)
        assert done and done[0].hops == 1          # went via peer
        assert rack.migrations and rack.migrations[0][1] == "snic0"

    def test_directed_migrate_to_stays_put(self):
        """Placer-driven migration: migrate_to() launches at the chosen
        peer, detours traffic via the MAT rule, and does NOT poll to
        migrate back (deploy-on-new + drain-old, not overload spill)."""
        sim = EventSim()
        rack = make_rack(sim, 3, SPECS,
                         cfg_kw=dict(n_regions=2, region_slots=4,
                                     enable_drf=False,
                                     enable_autoscale=False))
        a, _b, c = rack.snics
        d1 = chain_dag(1, "u1", ("NT1",))
        a.deploy([d1])
        sim.run(PAPER.PR_NS + 1)
        assert rack.migrate_to(a, c, 1)        # directed: skip the closer b
        done = []
        c.done_hook = lambda p: done.append(p)
        sim.run(sim.now + PAPER.PR_NS + 1)
        a.inject("u1", 1, 500)
        sim.run(sim.now + 1 * MS)
        assert done and done[0].hops == 1      # served by c via the detour
        assert rack.migrations[-1][1] == a.cfg.name
        assert rack.migrations[-1][2] == c.cfg.name
        # a has free regions the whole time, yet the chain must NOT bounce
        # back home (directed moves carry no migrate-back poll)
        sim.run(sim.now + 20 * MS)
        assert 1 in a.remote_dags

    def test_remote_memory_pooling(self):
        sim = EventSim()
        rack = make_rack(sim, 2, SPECS, cfg_kw=dict(
            enable_drf=False, enable_autoscale=False))
        a = rack.snics[0]
        a.vmem.n_frames = 2
        a.vmem.free_frames = [1, 0]
        a.vmem.register("x")
        a.vmem.access("x", 0, 0.0)
        a.vmem.access("x", 1, 0.0)
        # peer has free memory -> over-subscription allowed
        lat = a.vmem.access("x", 2, 1.0)
        assert lat >= a.vmem.swap_ns
