"""Serving engine + case-study tests (KV store, VPC chain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.serving.engine import Engine, EngineConfig


@pytest.fixture(scope="module")
def engine_cfg():
    cfg = configs.get_tiny_config("musicgen-medium").replace(
        frontend="tokens", vocab_size=64)
    return cfg


def prompts(n, lo=4, hi=12, seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [rng.integers(2, vocab, rng.integers(lo, hi)).astype(np.int32)
            for _ in range(n)]


class TestEngine:
    def test_generate_correctness_vs_direct(self, engine_cfg):
        """Engine output == direct prefill+decode for a single request."""
        from repro.models import model as MD
        cfg = engine_cfg
        eng = Engine(cfg, EngineConfig(batch_sizes=(1,), max_len=64,
                                       enable_cache_nt=False), seed=1)
        p = np.arange(3, 9, dtype=np.int32)
        req = eng.submit("t0", p, max_new=6)
        eng.run_until_drained()
        # direct reference
        logits, cache = MD.apply_prefill(eng.params, cfg,
                                         {"tokens": jnp.asarray(p)[None]},
                                         max_len=64)
        toks = []
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = len(p)
        for i in range(6):
            toks.append(int(tok[0]))
            if i == 5:
                break
            logits, cache = MD.apply_decode(eng.params, cfg, cache,
                                            {"tokens": tok[:, None]},
                                            jnp.int32(pos + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert req.out == toks, (req.out, toks)

    def test_cache_nt_hit(self, engine_cfg):
        eng = Engine(engine_cfg, EngineConfig(batch_sizes=(1,), max_len=64),
                     seed=2)
        p = np.arange(3, 9, dtype=np.int32)
        r1 = eng.submit("t0", p, max_new=4)
        eng.run_until_drained()
        r2 = eng.submit("t0", p, max_new=4)
        eng.run_until_drained()
        assert not r1.cached and r2.cached
        assert r2.out == r1.out
        assert eng.cache_nt.hits == 1

    def test_multi_tenant_drf_fairness(self, engine_cfg):
        """A flooding tenant must not starve a light tenant (DRF admission):
        the light tenant's requests all complete within the first epochs."""
        eng = Engine(engine_cfg, EngineConfig(batch_sizes=(1, 2, 4),
                                              max_len=64,
                                              enable_cache_nt=False,
                                              epoch_requests=4), seed=3)
        for p in prompts(40, seed=1):
            eng.submit("heavy", p, max_new=4)
        for p in prompts(4, seed=2):
            eng.submit("light", p, max_new=4)
        for _ in range(6):
            eng.step()
        light_done = [r for r in eng.done if r.tenant == "light"]
        assert len(light_done) >= 2, len(light_done)

    def test_autoscale_batch_shape(self, engine_cfg):
        """Backlog growth scales the decode batch out; drain scales down
        ("instance autoscaling"); compile log records the PR analogue."""
        eng = Engine(engine_cfg, EngineConfig(batch_sizes=(1, 2, 4),
                                              max_len=64,
                                              enable_cache_nt=False,
                                              epoch_requests=8), seed=4)
        assert eng.active_bs == 1
        for p in prompts(24, seed=5):
            eng.submit("t", p, max_new=2)
        eng.step()
        assert eng.active_bs > 1
        eng.run_until_drained()
        assert any(k == "decode" for k, _, _ in eng.compile_log)

    def test_prelaunch_avoids_inline_compile(self, engine_cfg):
        eng = Engine(engine_cfg, EngineConfig(batch_sizes=(1, 2), max_len=64),
                     seed=5)
        eng.prelaunch()
        n_compiles = len(eng.compile_log)
        for p in prompts(4, seed=6):
            eng.submit("t", p, max_new=2)
        eng.run_until_drained()
        assert len(eng.compile_log) == n_compiles  # nothing new compiled

    def test_kv_page_accounting(self, engine_cfg):
        eng = Engine(engine_cfg, EngineConfig(batch_sizes=(1,), max_len=64,
                                              mem_pages=4, page_tokens=8,
                                              enable_cache_nt=False), seed=6)
        for p in prompts(3, lo=30, hi=34, seed=7):
            eng.submit("t", p, max_new=16)
        eng.run_until_drained(max_iters=40)
        # vmem gets exercised and all pages are released afterwards
        assert eng.vmem.stats.allocs > 0
        assert len(eng.vmem.free_frames) == eng.vmem.n_frames


class TestKVStore:
    def test_cache_improves_latency_and_tput(self):
        from repro.serving.kv_store import run_ycsb
        base = run_ycsb("clio-snic", workload="C", n_ops=8000, n_keys=20000)
        cache = run_ycsb("clio-snic-cache", workload="C", n_ops=8000,
                         n_keys=20000, cache_entries=2048)
        assert cache.avg_us < base.avg_us
        assert cache.hits > 0
        assert cache.kops(cache.done_ns) > base.kops(base.done_ns)

    def test_snic_transport_offload_overhead_small(self):
        """Paper: sNIC adds only a small overhead over direct Clio."""
        from repro.serving.kv_store import run_ycsb
        clio = run_ycsb("clio", workload="C", n_ops=6000)
        snic = run_ycsb("clio-snic", workload="C", n_ops=6000)
        assert snic.avg_us < clio.avg_us * 1.35

    def test_replication_nt_cheaper_than_client_side(self):
        from repro.serving.kv_store import run_ycsb
        client = run_ycsb("clio", workload="A", n_ops=6000, replication=2)
        snic = run_ycsb("clio-snic-repl", workload="A", n_ops=6000,
                        replication=2)
        assert snic.avg_us < client.avg_us

    def test_zipf_is_skewed(self):
        from repro.serving.kv_store import zipf_keys
        ks = zipf_keys(1000, 5000, seed=1)
        top = sum(1 for k in ks if k < 10)
        assert top > 1000  # top-1% keys get >20% of accesses


class TestVPC:
    def test_firewall_rules(self):
        from repro.serving.vpc import firewall
        import jax.numpy as jnp
        # one deny-rule for 10.0.0.0/8 (0x0A000000)
        rules = (jnp.asarray([0x0A000000], jnp.uint32),
                 jnp.asarray([0xFF000000], jnp.uint32),
                 jnp.asarray([False]))
        h_deny = jnp.asarray([[1, 0x0A010203, 2, 3, 4]], jnp.uint32)
        h_allow = jnp.asarray([[1, 0x0B010203, 2, 3, 4]], jnp.uint32)
        assert not bool(firewall(h_deny, rules)[0])
        assert bool(firewall(h_allow, rules)[0])

    def test_nat_deterministic_and_rewrites(self):
        from repro.serving.vpc import make_packets, nat_rewrite
        h, _ = make_packets(16, seed=2)
        out1 = nat_rewrite(h, 0x0A000001)
        out2 = nat_rewrite(h, 0x0A000001)
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        assert (np.asarray(out1)[:, 0] == 0x0A000001).all()
        np.testing.assert_array_equal(np.asarray(out1)[:, 1],
                                      np.asarray(h)[:, 1])  # dst unchanged

    def test_chacha_jnp_matches_rfc_ref(self):
        from repro.kernels.chacha20.ref import chacha20_xor_ref
        from repro.serving.vpc import chacha20_xor_jnp
        rng = np.random.default_rng(3)
        data = rng.integers(0, 2 ** 32, (8, 16), dtype=np.uint32)
        key = rng.integers(0, 2 ** 32, (8,), dtype=np.uint32)
        nonce = rng.integers(0, 2 ** 32, (3,), dtype=np.uint32)
        out = chacha20_xor_jnp(jnp.asarray(data), jnp.asarray(key),
                               jnp.asarray(nonce))
        np.testing.assert_array_equal(np.asarray(out),
                                      chacha20_xor_ref(data, key, nonce))

    def test_chain_end_to_end(self):
        from repro.serving.vpc import make_packets, make_rules, vpc_chain
        h, p = make_packets(64, seed=4)
        rules = make_rules(8, seed=5)
        key = jnp.arange(8, dtype=jnp.uint32)
        nonce = jnp.arange(3, dtype=jnp.uint32)
        allow, newh, ct = vpc_chain(h, p, rules, key, nonce)
        assert allow.shape == (64,)
        # encryption is invertible for allowed packets
        from repro.serving.vpc import chacha20_xor_jnp
        pt = chacha20_xor_jnp(ct, key, nonce)
        ok = np.asarray(allow)
        np.testing.assert_array_equal(np.asarray(pt)[ok],
                                      np.asarray(p)[ok])
