"""Dry-run machinery tests on a small forced-device mesh (subprocess):
lower+compile one representative cell per family on a 4x2 mesh and check
the JSON record pipeline + collective parser."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes
    hlo = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[8,128]{1,0} %x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %w)
  %notacoll = f32[2]{0} add(f32[2]{0} %a, f32[2]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 256 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["counts"]["all-gather"] == 1
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("counts", "total"))


@pytest.mark.parametrize("arch,shape", [
    ("yi-6b", "decode_32k"),            # dense serve, fsdp_only arch
    ("jamba-v0.1-52b", "train_4k"),     # hybrid+MoE+EP train
])
def test_small_mesh_cell_compiles(arch, shape, tmp_path):
    """The same run_cell path used for the 512-chip dry-run compiles tiny
    reduced configs on an in-process 4x2 mesh."""
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {SRC!r})
import json
from pathlib import Path
from repro import configs

# shrink the arch (keep family structure) and the shape
cfg = configs.get_tiny_config({arch!r}).replace(scan_layers=True)
if not cfg.is_homogeneous():
    cfg = cfg.replace(scan_layers=False)
orig_get, orig_shapes = configs.get_config, dict(configs.SHAPES)
configs.get_config = lambda a: cfg if a == {arch!r} else orig_get(a)
from repro.configs.base import ShapeConfig
sh = orig_shapes[{shape!r}]
configs.SHAPES[{shape!r}] = ShapeConfig(sh.name, 256, 8, sh.kind)

import repro.launch.dryrun as DR
DR.make_mesh_by_name = lambda name: __import__("jax").make_mesh(
    (4, 2), ("data", "model"))
rec = DR.run_cell({arch!r}, {shape!r}, "single",
                  out_dir=Path({str(tmp_path)!r}), verbose=False)
assert rec["cost"]["flops"] > 0
assert rec["memory"]["temp_size_in_bytes"] is not None
print("CELL_OK", rec["collectives"]["total"])
"""
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=560,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert "CELL_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-3000:]
