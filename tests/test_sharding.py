"""Sharding-rule and distribution tests on small in-process meshes.

These run with the default single CPU device for rule/unit checks and use a
subprocess with forced host devices for real multi-device pjit execution
(numerical equivalence of sharded vs single-device training steps).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.launch import steps as ST

SRC = str(Path(__file__).resolve().parents[1] / "src")


class FakeMesh:
    """Just enough Mesh surface for the spec builders."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestParamSpecs:
    def setup_method(self):
        from repro.parallel import sharding as SH
        self.SH = SH
        self.mesh = FakeMesh({"data": 16, "model": 16})

    def _specs(self, arch):
        cfg = configs.get_config(arch)
        params = ST.abstract_params(cfg)
        return params, self.SH.param_specs(params, self.mesh)

    def test_dense_rules(self):
        params, specs = self._specs("yi-6b")
        # stacked layers: leading None then (fsdp, TP)
        assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
        assert specs["layers"]["attn"]["wo"]["w"] == P(None, "model", "data")
        assert specs["layers"]["mlp"]["down"]["w"] == P(None, "model", "data")
        assert specs["layers"]["norm1"]["g"] == P(None, None)
        assert specs["embed"]["table"] == P("model", None)
        assert specs["head"]["w"] == P("data", "model")

    def test_moe_rules(self):
        params, specs = self._specs("grok-1-314b")
        assert specs["layers"]["moe"]["gate"] == P(None, None, "data", "model")
        assert specs["layers"]["moe"]["down"] == P(None, None, "model", "data")
        assert specs["layers"]["moe"]["router"]["w"] == P(None, None, None)

    def test_nondivisible_dims_dropped(self):
        # granite vocab 49155 is not divisible by 16: spec must drop the axis
        params, specs = self._specs("granite-moe-1b-a400m")
        assert specs["embed"]["table"] == P(None, None)

    def test_every_leaf_divides(self):
        import numpy as np
        for arch in configs.ARCH_NAMES:
            cfg = configs.get_config(arch)
            params = ST.abstract_params(cfg)
            specs = self.SH.param_specs(params, self.mesh)

            def check(path, leaf, spec):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    n = int(np.prod([self.mesh.shape[a] for a in axes]))
                    assert dim % n == 0, (arch, path, leaf.shape, spec)
            jax.tree_util.tree_map_with_path(
                lambda p, l, s: check(p, l, s), params, specs,
                is_leaf=lambda x: hasattr(x, "shape"))

    def test_cache_specs_batch_vs_seq(self):
        from repro.parallel import sharding as SH
        cfg = configs.get_config("yi-6b")
        cache = ST.abstract_cache(cfg, 128, 1024)
        specs = SH.cache_specs(cfg, cache, self.mesh, 128)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        # batch sharded over data, seq over model
        assert P(None, "data", "model", None, None) in leaves
        # B=1: batch unshardable -> seq over everything
        cache1 = ST.abstract_cache(cfg, 1, 1024)
        specs1 = SH.cache_specs(cfg, cache1, self.mesh, 1)
        leaves1 = jax.tree_util.tree_leaves(
            specs1, is_leaf=lambda x: isinstance(x, P))
        assert P(None, None, ("data", "model"), None, None) in leaves1


class TestShardedExecution:
    """Sharded training step == single-device step, bit-for-bit-ish."""

    @pytest.mark.parametrize("arch", ["yi-6b", "granite-moe-1b-a400m"])
    def test_sharded_step_matches_single(self, arch):
        script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {SRC!r})
import jax, numpy as np
import jax.numpy as jnp
from repro import configs
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import adamw
from repro.parallel import sharding as SH, ctx as pctx

cfg = configs.get_tiny_config({arch!r}).replace(scan_layers=True)
params = init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)
batch = SyntheticLM(cfg, 8, 64, seed=0).batch(0)
step = make_train_step(cfg, lr=1e-3)

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)
l1 = float(m1["loss"])

# sharded 4x2
mesh = jax.make_mesh((4, 2), ("data", "model"))
pspec = SH.param_specs(params, mesh)
with mesh, pctx.policy(mesh):
    sharded = jax.jit(step, in_shardings=(
        SH.to_shardings(pspec, mesh),
        type(o1)(m=SH.to_shardings(pspec, mesh),
                 v=SH.to_shardings(pspec, mesh),
                 count=jax.sharding.NamedSharding(
                     mesh, jax.sharding.PartitionSpec())),
        SH.to_shardings(SH.batch_specs(batch, mesh), mesh)))
    p2, o2, m2 = sharded(params, opt, batch)
l2 = float(m2["loss"])
assert abs(l1 - l2) < 5e-4, (l1, l2)
# updated params agree
d = max(float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 5e-3, d
print("SHARDED_OK", l1, l2, d)
"""
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=560,
                           env={**os.environ, "PYTHONPATH": SRC})
        assert "SHARDED_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-3000:]


class TestDryrunArtifacts:
    """The committed dry-run records cover every applicable cell x mesh."""

    DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

    def test_all_cells_present(self):
        if not self.DIR.exists():
            pytest.skip("dry-run artifacts not generated yet")
        missing = []
        for a, s, ok, _ in configs.all_cells():
            for m in ("single", "multi"):
                if not (self.DIR / f"{a}__{s}__{m}.json").exists():
                    missing.append((a, s, m))
        assert not missing, missing

    def test_records_sane(self):
        import json
        if not self.DIR.exists():
            pytest.skip("dry-run artifacts not generated yet")
        for fn in self.DIR.glob("*.json"):
            rec = json.loads(fn.read_text())
            assert rec["cost"].get("flops", 0) > 0, fn.name
            assert rec["n_chips"] in (256, 512), fn.name
            if rec["mesh"] == "multi":
                assert rec["n_chips"] == 512
