"""Roofline math unit tests (pure functions; no compiles)."""
from __future__ import annotations

import json

from repro.roofline.analysis import HBM_BW, LINK_BW, PEAK_FLOPS, Terms, summarize


class TestTerms:
    def test_dominant_and_fraction(self):
        t = Terms(compute_s=1.0, memory_s=2.0, collective_s=0.5)
        assert t.dominant == "memory"
        assert t.bound_s == 2.0
        assert t.compute_fraction == 0.5

    def test_compute_bound_ideal(self):
        t = Terms(compute_s=3.0, memory_s=1.0, collective_s=1.0)
        assert t.dominant == "compute"
        assert t.compute_fraction == 1.0

    def test_hardware_constants(self):
        # v5e: 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
        assert PEAK_FLOPS == 197e12
        assert HBM_BW == 819e9
        assert LINK_BW == 50e9


def test_summarize_table_shape():
    recs = [dict(arch="a", shape="s", compute_s=1e-3, memory_s=2e-3,
                 collective_s=3e-3, dominant="collective",
                 compute_fraction=0.33, useful_flops_ratio=0.9)]
    md = summarize(recs)
    lines = md.splitlines()
    assert lines[0].startswith("| arch ")
    assert "**collective**" in lines[2]
    assert "0.33" in lines[2]


def test_extrapolation_math():
    """base + (L/period)*per_period recovers linear-in-depth totals."""
    L, period = 32, 8
    per_layer_true, base_true = 7.0, 100.0
    t1 = base_true + period * per_layer_true
    t2 = base_true + 2 * period * per_layer_true
    per_period = t2 - t1
    base = t1 - per_period
    total = base + (L / period) * per_period
    assert total == base_true + L * per_layer_true
