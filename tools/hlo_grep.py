import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.parallel import sharding as SH, ctx as pctx

arch, shape, meshname, pattern = sys.argv[1], sys.argv[2], sys.argv[3], sys.argv[4]
mesh = make_production_mesh(multi_pod=(meshname == "multi"))
cell = input_specs(arch, shape)
in_specs = []
for i, a in enumerate(cell.args):
    if i == 0:
        in_specs.append(SH.param_specs(a, mesh))
    elif cell.kind == "train" and i == 1:
        pspec = SH.param_specs(cell.args[0], mesh)
        in_specs.append(type(a)(m=pspec, v=pspec, count=jax.sharding.PartitionSpec()))
    elif cell.kind == "decode" and i == 1:
        in_specs.append(SH.cache_specs(cell.cfg, a, mesh, cell.shape.global_batch))
    elif isinstance(a, dict):
        in_specs.append(SH.batch_specs(a, mesh))
    else:
        in_specs.append(jax.sharding.PartitionSpec())
with mesh, pctx.policy(mesh):
    compiled = jax.jit(cell.step, in_shardings=SH.to_shardings(tuple(in_specs), mesh),
                       donate_argnums=cell.donate).lower(*cell.args).compile()
hlo = compiled.as_text()
pat = re.compile(pattern)
n = 0
for line in hlo.splitlines():
    if pat.search(line):
        print(line.strip()[:240])
        n += 1
        if n >= int(sys.argv[5] if len(sys.argv) > 5 else 20): break
