"""Shim: the HLO tooling lives in repro.analysis.hlo now.

    PYTHONPATH=src python tools/hlo_grep.py ARCH SHAPE MESH PATTERN [LIMIT]
    (same as: python -m repro.analysis hlo grep ...)
"""
import sys

from repro.analysis.hlo import main_grep

if __name__ == "__main__":
    arch, shape, mesh, pattern = sys.argv[1:5]
    limit = int(sys.argv[5]) if len(sys.argv) > 5 else 20
    raise SystemExit(main_grep(arch, shape, mesh, pattern, limit))
