"""Assemble EXPERIMENTS.md from measured artifacts:
experiments/dryrun/*.json, experiments/roofline/*.json, experiments/bench/*.json
plus the hand-written §Perf iteration log (tools/perf_log.md).
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRY = ROOT / "experiments" / "dryrun"
ROOF = ROOT / "experiments" / "roofline"
BENCH = ROOT / "experiments" / "bench"
PERF_LOG = ROOT / "tools" / "perf_log.md"

sys.path.insert(0, str(ROOT / "src"))
from repro import configs  # noqa: E402

SKIPS = [(a, s, why) for a, s, ok, why in configs.all_cells(True) if not ok]


def load(d: Path):
    out = {}
    for fn in sorted(d.glob("*.json")):
        out[fn.stem] = json.loads(fn.read_text())
    return out


def dryrun_section() -> str:
    recs = load(DRY)
    lines = [
        "## §Dry-run — every (architecture × shape) × {single-pod 16×16, "
        "multi-pod 2×16×16}",
        "",
        "`PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both` — "
        "every cell below lowered **and compiled** (`.lower().compile()`), "
        "with `memory_analysis()` / `cost_analysis()` captured to "
        "`experiments/dryrun/*.json`.",
        "",
        "Memory-analysis caveat (recorded per cell): XLA:CPU promotes bf16 "
        "compute to f32 inside fusions, so `temp` is a ≈2× upper bound on "
        "bf16-heavy cells relative to a real TPU lowering.",
        "",
        "| arch | shape | mesh | chips | compile s | args GB/dev | "
        "temp GB/dev | HLO flops/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(recs):
        r = recs[key]
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r['compile_s']} | {(m['argument_size_in_bytes'] or 0)/1e9:.2f} "
            f"| {(m['temp_size_in_bytes'] or 0)/1e9:.2f} "
            f"| {r['cost'].get('flops', 0):.3g} "
            f"| {r['collectives']['total']/1e9:.2f} |")
    lines.append("")
    lines.append(f"**Cells compiled: {len(recs)}** "
                 f"(32 applicable cells × 2 meshes).")
    lines.append("")
    lines.append("Skipped cells (per assignment, documented in DESIGN.md §5):")
    for a, s, why in SKIPS:
        lines.append(f"- {a} × {s}: {why}")
    return "\n".join(lines)


def roofline_section() -> str:
    recs = load(ROOF)
    lines = [
        "## §Roofline — per (arch × shape), single-pod 16×16 (256 chips)",
        "",
        "Terms derived from the compiled dry-run (TPU v5e: 197 TF/s bf16, "
        "819 GB/s HBM, 50 GB/s/link ICI). FLOPs/bytes use L=1/L=2 unrolled "
        "compiles extrapolated to the full depth (XLA cost analysis counts "
        "`while` bodies once); collective bytes parsed from the partitioned "
        "HLO (per-device operand bytes of all-gather / all-reduce / "
        "reduce-scatter / all-to-all / collective-permute).",
        "",
        "`compute frac` = compute_term / max(all terms): the fraction of the "
        "roofline-bound step the MXU is busy (1.0 = compute-bound ideal). "
        "`useful ratio` = MODEL_FLOPS (6·N·D train, 2·N·D prefill, 2·N_active"
        "·B decode) / HLO FLOPs — values < 1 count remat recompute, "
        "attention quadratics, and dispatch overheads; decode values are "
        "small because attention over the 32k cache dominates parameter "
        "FLOPs there.",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "compute frac | useful ratio | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    NOTES = {
        ("train", "collective"): "less TP/SP traffic (fsdp_only) or fewer "
                                 "microbatch re-gathers",
        ("train", "memory"): "less remat recompute traffic; bf16 buffers "
                             "(CPU analysis inflates to f32)",
        ("train", "compute"): "at the roofline knee — larger per-device "
                              "batch or faster kernels",
        ("prefill", "collective"): "weight-resident (TP-only) sharding; "
                                   "KV-only seq gathers",
        ("prefill", "memory"): "flash-attention kernel (skip masked blocks, "
                               "fewer score-buffer passes)",
        ("prefill", "compute"): "Pallas flash kernel halves masked-block "
                                "FLOPs",
        ("decode", "memory"): "in-place KV update (carry+dus), int8 KV, "
                              "larger decode batch per chip",
        ("decode", "collective"): "keep weights resident (TP-only serve "
                                  "sharding)",
        ("decode", "compute"): "decode is bandwidth-bound by design",
    }
    for key in sorted(recs):
        r = recs[key]
        note = NOTES.get((r["kind"], r["dominant"]), "")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} "
            f"| {r['memory_s']:.3e} | {r['collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['compute_fraction']:.2f} "
            f"| {r['useful_flops_ratio']:.2f} | {note} |")

    # aggregate summary
    vals = list(recs.values())
    train = [r for r in vals if r["kind"] == "train"]
    if train:
        doms = {}
        for r in vals:
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
        best = max(train, key=lambda r: r["compute_fraction"])
        worst = min(train, key=lambda r: r["compute_fraction"])
        lines += [
            "",
            f"**Summary** ({len(vals)} cells): dominant terms — {doms}. "
            f"Train compute fractions span {worst['compute_fraction']:.2f} "
            f"({worst['arch']}) to {best['compute_fraction']:.2f} "
            f"({best['arch']}); decode cells are memory-bound by design "
            f"(KV reads), prefill cells remain collective-bound (seq "
            f"gathers around the q-block scan — the Pallas flash kernel / "
            f"ring attention is the next step on hardware). The memory "
            f"term carries the XLA:CPU bf16→f32 inflation (~2× on "
            f"bf16-heavy cells): TPU-estimated compute fractions for the "
            f"memory-bound train cells are roughly double the listed "
            f"values (e.g. yi-6b train ≈ 0.9, stablelm-12b ≈ 0.9+).",
        ]
    return "\n".join(lines)


def bench_section() -> str:
    """Figure sweeps from experiments/bench/ plus the canonical repo-root
    BENCH_*.json snapshots (the single source of truth benchmarks/run.py
    maintains; nested sections render as their scalar headline keys)."""
    recs = load(BENCH)
    for fn in sorted(ROOT.glob("BENCH_*.json")):
        if fn.stem == "BENCH_trajectory":
            continue            # the ledger is an artifact, not a figure
        recs[fn.stem] = json.loads(fn.read_text())
    lines = ["## §Paper-figure reproduction (benchmarks/run.py)", ""]
    for key in sorted(recs):
        r = recs[key]
        lines.append(f"### {key}")
        lines.append("")
        lines.append("| metric | value |")
        lines.append("|---|---|")
        for k, v in r.items():
            if k.startswith("_") or isinstance(v, (list, dict)):
                continue
            lines.append(f"| {k} | {v} |")
        lines.append("")
    return "\n".join(lines)


def main():
    parts = [
        "# EXPERIMENTS",
        "",
        "All numbers in this file are produced by committed code: "
        "`repro.launch.dryrun` (§Dry-run), `repro.roofline.analysis` "
        "(§Roofline), `benchmarks.run` (figure reproductions), and the "
        "hillclimb scripts referenced in §Perf.",
        "",
        dryrun_section(),
        "",
        roofline_section(),
        "",
        PERF_LOG.read_text() if PERF_LOG.exists() else "## §Perf\n(pending)",
        "",
        bench_section(),
    ]
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(parts))
    print(f"wrote EXPERIMENTS.md ({len((ROOT / 'EXPERIMENTS.md').read_text())} bytes)")


if __name__ == "__main__":
    main()
