import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys, collections
import jax
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.parallel import sharding as SH, ctx as pctx

arch, shape, meshname = sys.argv[1], sys.argv[2], sys.argv[3]
mesh = make_production_mesh(multi_pod=(meshname == "multi"))
cell = input_specs(arch, shape)
in_specs = []
for i, a in enumerate(cell.args):
    if i == 0:
        in_specs.append(SH.param_specs(a, mesh))
    elif cell.kind == "train" and i == 1:
        pspec = SH.param_specs(cell.args[0], mesh)
        in_specs.append(type(a)(m=pspec, v=pspec, count=jax.sharding.PartitionSpec()))
    elif cell.kind == "decode" and i == 1:
        in_specs.append(SH.cache_specs(cell.cfg, a, mesh, cell.shape.global_batch))
    elif isinstance(a, dict):
        in_specs.append(SH.batch_specs(a, mesh))
    else:
        in_specs.append(jax.sharding.PartitionSpec())
with mesh, pctx.policy(mesh):
    compiled = jax.jit(cell.step, in_shardings=SH.to_shardings(tuple(in_specs), mesh),
                       donate_argnums=cell.donate).lower(*cell.args).compile()
hlo = compiled.as_text()
BY = {"f64":8,"f32":4,"f16":2,"bf16":2,"s64":8,"u64":8,"s32":4,"u32":4,"s16":2,"u16":2,"s8":1,"u8":1,"pred":1}
pat = re.compile(r"^\s*%?\S+ = (f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]+)\][^ ]* (\S+)")
sizes = collections.Counter()
for line in hlo.splitlines():
    m = pat.match(line)
    if not m: continue
    n = 1
    for d in m.group(2).split(","): n *= int(d)
    b = n * BY[m.group(1)]
    if b > 100e6:
        sizes[f"{m.group(3)[:30]} {m.group(1)}[{m.group(2)}]"] += b  # aggregate identical shapes
for k, v in sizes.most_common(25):
    print(f"{v/1e9:8.2f} GB  {k}")
ma = compiled.memory_analysis()
print("temp GB:", ma.temp_size_in_bytes/1e9)
