"""Shim: the HLO tooling lives in repro.analysis.hlo now.

    PYTHONPATH=src python tools/hlo_top_buffers.py ARCH SHAPE MESH
    (same as: python -m repro.analysis hlo buffers ...)
"""
import sys

from repro.analysis.hlo import main_buffers

if __name__ == "__main__":
    arch, shape, mesh = sys.argv[1:4]
    raise SystemExit(main_buffers(arch, shape, mesh))
